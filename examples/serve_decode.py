"""Serving example: batched decoding with KV caches / recurrent state.

Covers three families: dense local:global (gemma3), hybrid (recurrentgemma)
and attention-free (rwkv6) — all through the same ServeEngine, twice:

* lockstep ``generate``: one batch, every request padded to the longest;
* continuous ``serve``: a ragged request queue through 2 slots with
  per-request budgets, temperature/top-k sampling inside the jitted
  window, and EOS-freed slots recycled to the next queued request —
  plus the fault-isolation layer: a chaos-injected NaN is quarantined
  in-window and recovered by re-prefill (typed ``recovered`` outcome),
  a per-request deadline and a bounded queue produce ``deadline`` /
  ``shed`` outcomes, and neighbors stay bit-identical throughout.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.model import model as M
from repro.serve.engine import Request, ServeEngine

ARCHS = ["gemma3-1b", "recurrentgemma-2b", "rwkv6-1.6b"]


def main():
    rng = np.random.default_rng(0)
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = M.init_params(cfg, jax.random.key(0))
        # decode_window=8: one-jit prompt prefill, then 16 tokens in
        # ceil(16/8)=2 decode dispatches with donated (in-place) state.
        engine = ServeEngine(cfg, params, max_len=96, decode_window=8)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 12)), jnp.int32)
        t0 = time.perf_counter()
        out = engine.generate(prompts, num_new_tokens=16)
        dt = time.perf_counter() - t0
        print(f"{arch:22s} lockstep   -> {out.shape} in {dt:.2f}s "
              f"({engine.last_decode_dispatches} decode dispatches); "
              f"sample: {np.asarray(out[0, -6:]).tolist()}")

        # Continuous batching: 6 ragged requests through 2 slots.  Each
        # request decodes at its own position and frees its slot the
        # moment its budget (or EOS) hits — detected inside the jit.
        reqs = [
            Request(
                tokens=jnp.asarray(
                    rng.integers(0, cfg.vocab_size,
                                 (int(rng.integers(4, 13)),)), jnp.int32),
                max_new_tokens=int(rng.integers(3, 17)),
            )
            for _ in range(6)
        ]
        t0 = time.perf_counter()
        outs = engine.serve(reqs, slots=2, temperature=0.7, top_k=32, seed=0)
        dt = time.perf_counter() - t0
        st = engine.last_serve_stats
        print(f"{arch:22s} continuous -> {[int(o.size) for o in outs]} "
              f"tokens in {dt:.2f}s ({st['decode_dispatches']} dispatches, "
              f"{st['admissions']} admissions; outcomes "
              f"{sorted({o.outcome for o in outs})})")

        # Fault isolation: the same queue under chaos — one pinned
        # NaN-in-state fault (quarantined in-window, recovered by masked
        # re-prefill from the accepted prefix), one request on a
        # zero-millisecond deadline, and a 1-deep bounded queue that
        # sheds the last arrivals.  Every non-degraded request's stream
        # is bit-identical to the run above (same seed, per-(request,
        # token) sampling keys).
        from repro.serve.chaos import ChaosInjector

        chaos = ChaosInjector(seed=1, nan_at=(2,))
        c_outs = engine.serve(reqs, slots=2, temperature=0.7, top_k=32,
                              seed=0, chaos=chaos)
        st = engine.last_serve_stats
        identical = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(outs, c_outs))
        print(f"{arch:22s} chaos      -> outcomes "
              f"{[o.outcome for o in c_outs]} "
              f"({st['quarantines']} quarantined, {st['recoveries']} "
              f"recovered; streams bit-identical: {identical})")

        d_reqs = [Request(tokens=r.tokens, max_new_tokens=r.max_new_tokens,
                          deadline_ms=0.0 if i == 0 else None)
                  for i, r in enumerate(reqs)]
        d_outs = engine.serve(d_reqs, slots=2, temperature=0.7, top_k=32,
                              seed=0, max_queue=1)
        print(f"{arch:22s} lifecycle  -> outcomes "
              f"{[o.outcome for o in d_outs]} (deadline_ms=0 on request "
              f"0, queue bounded at 2 slots + 1)")


if __name__ == "__main__":
    main()
