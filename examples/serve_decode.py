"""Serving example: batched greedy decoding with KV caches / recurrent state.

Covers three families: dense local:global (gemma3), hybrid (recurrentgemma)
and attention-free (rwkv6) — all through the same ServeEngine.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.model import model as M
from repro.serve.engine import ServeEngine

ARCHS = ["gemma3-1b", "recurrentgemma-2b", "rwkv6-1.6b"]


def main():
    rng = np.random.default_rng(0)
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        params = M.init_params(cfg, jax.random.key(0))
        # decode_window=8: one-jit prompt prefill, then 16 tokens in
        # ceil(16/8)=2 decode dispatches with donated (in-place) state.
        engine = ServeEngine(cfg, params, max_len=96, decode_window=8)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 12)), jnp.int32)
        t0 = time.perf_counter()
        out = engine.generate(prompts, num_new_tokens=16)
        dt = time.perf_counter() - t0
        print(f"{arch:22s} -> {out.shape} in {dt:.2f}s "
              f"({engine.last_decode_dispatches} decode dispatches); "
              f"sample: {np.asarray(out[0, -6:]).tolist()}")


if __name__ == "__main__":
    main()
