"""Per-kernel microbenchmarks: jnp dispatch paths + interpret-mode checks.

Wall-clock timings on this container compare the *jnp* paths (the Pallas
kernels themselves are TPU-target; interpret mode is a correctness tool,
not a performance proxy).  Derived column reports the kernel's modeled
VMEM-resident traffic advantage.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.elevator_scan.ops import elevator_scan
from repro.kernels.elevator_scan.ref import elevator_scan_ref
from repro.kernels.local_attention.ref import attention_blockwise, attention_ref
from repro.kernels.token_shift.ops import token_shift
from repro.core import from_thread_or_const


def _time(fn, *args, reps=10):
    f = jax.jit(fn)
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def main():
    rng = np.random.default_rng(0)
    rows = []

    # elevator_scan: log-depth vs sequential reference.
    b, t, d = 4, 2048, 256
    a = jnp.asarray(rng.uniform(0.8, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    t_log = _time(lambda a_, x_: elevator_scan(a_, x_, use_kernel=False), a, x)
    t_seq = _time(elevator_scan_ref, a, x)
    rows.append(("elevator_scan_logdepth", t_log, f"seq_ref_us={t_seq:.0f}"))

    # token_shift vs unfused shifts.
    w = jnp.asarray(rng.standard_normal((4, d)).astype(np.float32))
    t_fused = _time(lambda x_, w_: token_shift(x_, w_, use_kernel=False), x, w)

    def unfused(x_, w_):
        out = jnp.zeros_like(x_)
        for k in range(4):
            out = out + w_[k] * jnp.pad(x_, ((0, 0), (k, 0), (0, 0)))[:, :t]
        return out

    t_unf = _time(unfused, x, w)
    rows.append(("token_shift", t_fused, f"unfused_us={t_unf:.0f}"))

    # blockwise attention vs full-matrix reference (memory win).
    q = jnp.asarray(rng.standard_normal((1, 4, 2048, 64)).astype(np.float32))
    t_block = _time(
        lambda q_: attention_blockwise(q_, q_, q_, causal=True, block=256), q
    )
    t_full = _time(lambda q_: attention_ref(q_, q_, q_, causal=True), q)
    rows.append(("attention_blockwise", t_block, f"full_ref_us={t_full:.0f}"))

    # elevator shift primitive.
    big = jnp.asarray(rng.standard_normal(1 << 20).astype(np.float32))
    t_shift = _time(lambda v: from_thread_or_const(v, 5, 0.0, window=4096), big)
    rows.append(("from_thread_or_const_1M", t_shift, "window=4096"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
