"""Per-kernel microbenchmarks: jnp dispatch paths + interpret-mode checks.

Wall-clock timings on this container compare the *jnp* paths (the Pallas
kernels themselves are TPU-target; interpret mode is a correctness tool,
not a performance proxy).  Derived column reports the kernel's modeled
VMEM-resident traffic advantage.

``main()`` prints the CSV block and returns the rows so
:mod:`benchmarks.run` can emit them machine-readable (BENCH_kernels.json).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import from_thread_or_const
from repro.core.cost_model import (
    serve_batch_steps,
    serve_fleet_drain,
    serve_prefix_admission,
    serve_recovery_steps,
    wkv_bwd_traffic,
    wkv_decode_token_io,
    wkv_decode_traffic,
    wkv_seqshard_traffic,
    wkv_traffic,
)
from repro.core.scratchpad import stage_through_memory
from repro.kernels.elevator_scan.ops import elevator_scan, elevator_scan_logdepth
from repro.kernels.elevator_scan.ref import elevator_scan_ref
from repro.kernels.local_attention.ref import attention_blockwise, attention_ref
from repro.kernels.token_shift.ops import token_shift
from repro.kernels.wkv.ops import wkv_fused
from repro.kernels.wkv.ref import wkv_chunked_ref


def _time(fn, *args, reps=10, jit=True):
    # Best-of-reps: the minimum is the noise-robust estimator on a shared
    # container (mean-of-reps flips close comparisons under load).
    f = jax.jit(fn) if jit else fn
    jax.block_until_ready(f(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _time_interleaved(fns, *args, reps=8):
    """Best-of-reps for several functions with rounds interleaved
    (A,B,...,A,B,...) so load drift on the container hits every candidate
    equally — the fair way to compare near-identical workloads."""
    jitted = [jax.jit(fn) for fn in fns]
    for f in jitted:
        jax.block_until_ready(f(*args))
    best = [float("inf")] * len(jitted)
    for _ in range(reps):
        for i, f in enumerate(jitted):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*args))
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e6 for b in best]


def wkv_unfused(r, k, v, w, u, h0, chunk: int = 64):
    """The pre-kernel WKV path rendered as Fig. 1b: the oracle's own math
    with every per-chunk intermediate (decay tensors, scores, scan carry)
    staged through a materialized buffer behind a barrier before its
    consumer reads it — the scratchpad pattern the fused kernel
    eliminates."""
    return wkv_chunked_ref(r, k, v, w, u, h0, chunk, stage=stage_through_memory)


def main(smoke: bool = False) -> list[dict]:
    """Returns the bench rows.  ``smoke=True`` (benchmarks/run.py --smoke)
    shrinks every shape and drops to one rep: a code-path regression check
    (imports, dispatch wiring, schema), not a measurement."""
    rng = np.random.default_rng(0)
    rows = []
    r_t = 1 if smoke else 10       # _time reps
    r_i = 1 if smoke else 8        # _time_interleaved reps

    # elevator_scan jnp dispatch (linear scan on CPU) vs the log-depth
    # associative scan vs the sequential reference.
    b, t, d = (2, 128, 64) if smoke else (4, 2048, 256)
    a = jnp.asarray(rng.uniform(0.8, 1.0, (b, t, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, t, d)).astype(np.float32))
    t_disp, t_log, t_seq = _time_interleaved(
        [
            lambda a_, x_: elevator_scan(a_, x_, use_kernel=False),
            elevator_scan_logdepth,
            elevator_scan_ref,
        ],
        a, x, reps=r_i,
    )
    rows.append((
        "elevator_scan_jnp", t_disp,
        f"logdepth_us={t_log:.0f} seq_ref_us={t_seq:.0f} "
        "(cpu dispatch: linear scan, unroll=2; associative_scan kept off-CPU)",
    ))

    # token_shift vs unfused shifts.
    w = jnp.asarray(rng.standard_normal((4, d)).astype(np.float32))
    t_fused = _time(lambda x_, w_: token_shift(x_, w_, use_kernel=False),
                    x, w, reps=r_t)

    def unfused(x_, w_):
        out = jnp.zeros_like(x_)
        for k in range(4):
            out = out + w_[k] * jnp.pad(x_, ((0, 0), (k, 0), (0, 0)))[:, :t]
        return out

    t_unf = _time(unfused, x, w, reps=r_t)
    rows.append(("token_shift", t_fused, f"unfused_us={t_unf:.0f}"))

    # wkv: fused dispatch vs the Fig. 1b staged path, (B=4, T=2048, D=256).
    bh, hh, tw, dh = (2, 2, 128, 16) if smoke else (4, 4, 2048, 64)
    chunk = 16 if smoke else 64
    rw = jnp.asarray(rng.standard_normal((bh, hh, tw, dh)).astype(np.float32))
    kw = jnp.asarray(rng.standard_normal((bh, hh, tw, dh)).astype(np.float32))
    vw = jnp.asarray(rng.standard_normal((bh, hh, tw, dh)).astype(np.float32))
    ww = jnp.asarray(rng.uniform(0.9, 0.999, (bh, hh, tw, dh)).astype(np.float32))
    uw = jnp.asarray(rng.standard_normal((hh, dh)).astype(np.float32))
    h0w = jnp.zeros((bh, hh, dh, dh), jnp.float32)
    t_wkv, t_wkv_chunked, t_wkv_staged = _time_interleaved(
        [
            lambda *args: wkv_fused(*args, chunk=chunk, use_kernel=False)[0],
            lambda *args: wkv_chunked_ref(*args, chunk=chunk)[0],
            lambda *args: wkv_unfused(*args, chunk=chunk)[0],
        ],
        rw, kw, vw, ww, uw, h0w, reps=r_i,
    )
    _, shared_cost, direct_cost = wkv_traffic(bh, hh, tw, dh, chunk)
    energy_red = shared_cost.energy_pj / max(direct_cost.energy_pj, 1e-9)
    rows.append((
        "wkv_fused", t_wkv,
        f"chunked_us={t_wkv_chunked:.0f} staged_us={t_wkv_staged:.0f} "
        f"modeled_energy_reduction={energy_red:.2f}",
    ))

    # wkv backward: the custom-VJP reverse sweep (its jnp rendering — the
    # manual chunked backward the kernel fuses, recompute-over-stage) vs
    # jax.grad of the raw chunked reference (residuals staged by autodiff).
    # The Pallas kernels themselves are TPU-target; as for the forward row,
    # CPU wall-clock compares the jnp dispatch paths.
    def _wkv_loss_vjp(*args):
        out, s_out = wkv_fused(*args, chunk=chunk, use_kernel=False)
        return out.sum() + s_out.sum()

    def _wkv_loss_autodiff(*args):
        out, s_out = wkv_chunked_ref(*args, chunk=chunk)
        return out.sum() + s_out.sum()

    grad_args = tuple(range(6))
    t_bwd_vjp, t_bwd_auto = _time_interleaved(
        [
            jax.grad(_wkv_loss_vjp, argnums=grad_args),
            jax.grad(_wkv_loss_autodiff, argnums=grad_args),
        ],
        rw, kw, vw, ww, uw, h0w, reps=r_i,
    )
    _, bwd_shared, bwd_direct = wkv_bwd_traffic(bh, hh, tw, dh, chunk)
    bwd_energy_red = bwd_shared.energy_pj / max(bwd_direct.energy_pj, 1e-9)
    rows.append((
        "wkv_bwd", t_bwd_vjp,
        f"autodiff_us={t_bwd_auto:.0f} "
        f"modeled_energy_reduction={bwd_energy_red:.2f} "
        "(recompute-over-stage: CPU wall-clock pays the recompute since"
        " staging is cheap there; the modeled win is staged bytes, see"
        " cost_model.wkv_bwd_traffic)",
    ))

    # wkv_seqshard: the sequence-parallel dispatch (segment-summary carry
    # across a mesh axis) vs the single-device fused path, same shapes.
    # On a 1-device container the seq axis is size 1 — the row then
    # measures pure protocol overhead; the multi-device CI lane
    # (scripts/tier1.sh) and TPU meshes exercise n > 1.  The modeled
    # column is the point of the protocol either way: bytes crossing the
    # seq axis at n=8, O(T·D) token re-gather vs O(Dh²) summary hops.
    from repro.kernels.wkv.seqpar import wkv_seqshard
    from repro.launch.mesh import make_seq_mesh

    n_dev = min(len(jax.devices()), 8)
    mesh = make_seq_mesh(n_dev)
    t_seqshard, t_single = _time_interleaved(
        [
            lambda *args: wkv_seqshard(
                *args, mesh=mesh, seq_axis="seq", chunk=chunk,
                use_kernel=False)[0],
            lambda *args: wkv_fused(*args, chunk=chunk, use_kernel=False)[0],
        ],
        rw, kw, vw, ww, uw, h0w, reps=r_i,
    )
    n_model = 8
    gather_cost, _, summary_cost = wkv_seqshard_traffic(bh, hh, tw, dh, n_model)
    crossed_ratio = gather_cost.traffic.dram_bytes / max(
        summary_cost.traffic.fabric_bytes, 1)
    # On a 1-device host the wall-clock column exercises no cross-device
    # protocol at all — say so outright rather than letting the row read
    # as a seq-parallel "speedup" (the multi-device lanes in
    # scripts/tier1.sh and TPU meshes measure n > 1).
    dev_note = (
        "n_dev=1 (layout overhead only, no cross-device hops) "
        if n_dev == 1
        else f"n_dev={n_dev} "
    )
    rows.append((
        "wkv_seqshard", t_seqshard,
        f"single_dev_us={t_single:.0f} {dev_note}"
        f"modeled_bytes_crossed_ratio_n{n_model}={crossed_ratio:.0f}x "
        "(O(T*D) token re-gather vs O(Dh^2) summary hops, "
        "cost_model.wkv_seqshard_traffic)",
    ))

    # analysis cross-check: seq-axis bytes counted out of the traced
    # jaxpr (the repro.analysis.collectives audit) vs the cost model at
    # this mesh size.  The tolerance is 5% — above it the cost model has
    # drifted from the program it claims to describe and the derived
    # columns of the rows above stop being trustworthy.  The wall-clock
    # column times the audit itself: the price of proving the protocol
    # statically before running it.
    from repro.analysis.collectives import counted_axis_elements

    t0 = time.perf_counter()
    seqshard_jaxpr = jax.make_jaxpr(
        lambda *args: wkv_seqshard(
            *args, mesh=mesh, seq_axis="seq", chunk=chunk,
            use_kernel=False))(rw, kw, vw, ww, uw, h0w)
    counted = counted_axis_elements(seqshard_jaxpr, "seq") * 4 * n_dev
    t_audit = (time.perf_counter() - t0) * 1e6
    modeled = wkv_seqshard_traffic(
        bh, hh, tw, dh, n_dev)[2].traffic.fabric_bytes
    div = abs(counted - modeled) / max(modeled, 1)
    rows.append((
        "analysis_crosscheck", t_audit,
        f"counted_bytes={counted} modeled_bytes={modeled} "
        f"divergence_pct={div * 100:.2f} tolerance_pct=5 n_dev={n_dev} "
        f"status={'DRIFT' if div > 0.05 else 'ok'} "
        "(jaxpr-counted seq-axis traffic vs cost_model.wkv_seqshard_traffic"
        "; repro.analysis.collectives.counted_axis_elements)",
    ))

    # wkv decode: persistent-state serve windows — per-token dispatch
    # (the pre-decode-kernel serve loop: one jit call per token) vs one
    # K-token window dispatch, tokens/s at K ∈ {1, 8, 32}.  CPU wall-clock
    # measures the jnp dispatch paths + per-dispatch overhead the window
    # amortizes; the modeled column is the state traffic the window kernel
    # removes on TPU (one HBM round-trip of S per window instead of per
    # token, cost_model.wkv_decode_traffic).
    db, dh_heads, ddh = (2, 2, 16) if smoke else (4, 4, 64)
    h0d = jnp.asarray(
        rng.standard_normal((db, dh_heads, ddh, ddh)).astype(np.float32))
    ud = jnp.asarray(rng.standard_normal((dh_heads, ddh)).astype(np.float32))

    def tok(k_):
        return [jnp.asarray(
            rng.standard_normal((db, dh_heads, k_, ddh)).astype(np.float32))
            for _ in range(3)] + [jnp.asarray(
                rng.uniform(0.9, 0.999, (db, dh_heads, k_, ddh))
                .astype(np.float32))]

    window_fn = jax.jit(
        lambda *args: wkv_fused(*args, decode=True, use_kernel=False))
    tok_s = {}
    for k_win in (1, 8, 32):
        rk, kk, vk, wk = tok(k_win)
        us = _time(window_fn, rk, kk, vk, wk, ud, h0d, reps=r_t, jit=False)
        tok_s[k_win] = k_win / us * 1e6
    tok_io = wkv_decode_token_io(db, dh_heads, ddh, 32)
    dec_naive, _, dec_direct = wkv_decode_traffic(db, dh_heads, ddh, 32)
    state_red = (dec_naive.traffic.dram_bytes - tok_io) / max(
        dec_direct.traffic.dram_bytes - tok_io, 1)
    rows.append((
        "wkv_decode", 1e6 / tok_s[1],
        f"tok_s_k1={tok_s[1]:.0f} tok_s_k8={tok_s[8]:.0f} "
        f"tok_s_k32={tok_s[32]:.0f} "
        f"modeled_state_bytes_per_token_reduction_k32={state_red:.0f}x "
        "(per-token S round-trip vs S-resident window, "
        "cost_model.wkv_decode_traffic)",
    ))

    # serve_continuous: the scheduler-level rendering of the same barrier
    # argument — lockstep decode (every request padded to the batch max,
    # batches in arrival order: a workgroup-global barrier) vs the
    # continuous engine (EOS/budget detection inside the jitted window,
    # freed slots re-prefilled from the queue: point-to-point hand-offs).
    # Wall-clock on a ragged workload, end-to-end through ServeEngine on
    # a reduced rwkv6; the modeled column is slot-step utilization
    # (cost_model.serve_batch_steps), which is model-independent.
    from repro.configs.registry import get_config
    from repro.model import model as model_mod
    from repro.serve.engine import Request, ServeEngine

    # Decode-heavy and strongly ragged (budgets 8..60): the regime the
    # scheduler targets — short prompts, long spreads, so lockstep's
    # pad-to-slowest barrier dominates and continuous refill wins.
    spec = (
        [(4, 4), (6, 2), (3, 6)] if smoke
        else [(5, 56), (7, 8), (4, 48), (3, 12),
              (6, 60), (8, 10), (5, 40), (4, 16)]
    )
    slots, s_window = 2, (2 if smoke else 4)
    s_cfg = get_config("rwkv6-1.6b").reduced()
    s_params = model_mod.init_params(s_cfg, jax.random.key(0))
    s_eng = ServeEngine(s_cfg, s_params, max_len=96, decode_window=s_window)
    s_reqs = [
        Request(tokens=jnp.asarray(
            rng.integers(0, s_cfg.vocab_size, (pl,)), jnp.int32),
            max_new_tokens=nn)
        for pl, nn in spec
    ]
    useful = sum(nn for _, nn in spec)

    def run_continuous():
        outs = s_eng.serve(s_reqs, slots=slots)
        assert sum(o.size for o in outs) == useful

    def run_lockstep():
        got = 0
        for i in range(0, len(s_reqs), slots):
            batch = s_reqs[i : i + slots]
            p_max = max(r.tokens.size for r in batch)
            prompts = np.zeros((len(batch), p_max), np.int32)
            plens = np.zeros(len(batch), np.int32)
            for b_i, r in enumerate(batch):
                prompts[b_i, : r.tokens.size] = np.asarray(r.tokens)
                plens[b_i] = r.tokens.size
            n_max = max(r.max_new_tokens for r in batch)
            out = s_eng.generate(jnp.asarray(prompts), n_max,
                                 prompt_lengths=jnp.asarray(plens))
            assert out.shape == (len(batch), p_max + n_max)
            # Useful tokens: each request's own budget out of the padded
            # n_max the lockstep barrier forces everyone through.
            got += sum(r.max_new_tokens for r in batch)
        assert got == useful

    for fn in (run_continuous, run_lockstep):   # compile warm-up
        fn()
    best = {"continuous": float("inf"), "lockstep": float("inf")}
    for _ in range(max(1, r_i // 2)):
        for name, fn in (("continuous", run_continuous),
                         ("lockstep", run_lockstep)):
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    tok_s_cont = useful / best["continuous"]
    tok_s_lock = useful / best["lockstep"]
    m_useful, m_lock, m_cont = serve_batch_steps(
        [nn for _, nn in spec], slots, s_window)
    rows.append((
        "serve_continuous", best["continuous"] * 1e6,
        f"tok_s_lockstep={tok_s_lock:.0f} tok_s_continuous={tok_s_cont:.0f} "
        f"modeled_slot_step_util_lockstep={m_useful / max(m_lock, 1):.2f} "
        f"modeled_slot_step_util_continuous={m_useful / max(m_cont, 1):.2f} "
        "(ragged budgets, EOS-free greedy; lockstep pads each arrival "
        "batch to its slowest member, cost_model.serve_batch_steps)",
    ))

    # serve_chaos: goodput under injected faults vs fault-free — the
    # robustness dual of the same barrier argument.  NaN-in-state faults
    # pinned to exactly 5% of decode dispatches (evenly spread, so the
    # drill is deterministic and the realized rate is the nominal rate);
    # each fault quarantines one slot inside the jitted window and
    # recovers it via an isolated masked re-prefill (never a
    # batch-global restart), so goodput degrades by the victim's replay
    # cost only.  Same workload at the engine's default slot pool (4),
    # against its own fault-free reference at identical settings.  The
    # modeled column is cost_model.serve_recovery_steps: per-slot
    # recovery vs restart-the-world, at this workload's mid-flight
    # state.
    from repro.serve.chaos import ChaosInjector

    slots_c = min(4, len(s_reqs))

    def run_ref():
        outs = s_eng.serve(s_reqs, slots=slots_c)
        assert sum(o.size for o in outs) == useful

    run_ref()                                   # compile warm-up
    n_disp = s_eng.last_serve_stats["decode_dispatches"]
    n_faults = max(1, round(0.05 * n_disp))
    pins = tuple(
        int(i) for i in
        np.linspace(0, n_disp - 1, n_faults + 2, dtype=int)[1:-1])

    def run_chaos():
        outs = s_eng.serve(s_reqs, slots=slots_c,
                           chaos=ChaosInjector(seed=7, nan_at=pins))
        assert sum(o.size for o in outs) == useful
        return s_eng.last_serve_stats["recoveries"]

    recov = run_chaos()                         # compile warm-up
    t_ref = t_chaos = float("inf")
    for _ in range(max(1, r_i // 2)):
        t0 = time.perf_counter()
        run_ref()
        t_ref = min(t_ref, time.perf_counter() - t0)
        t0 = time.perf_counter()
        recov = run_chaos()
        t_chaos = min(t_chaos, time.perf_counter() - t0)
    goodput_ratio = t_ref / t_chaos
    m_iso, m_glob = serve_recovery_steps(
        [pl for pl, _ in spec[:slots_c]],
        [nn // 2 for _, nn in spec[:slots_c]], 0, s_window)
    rows.append((
        "serve_chaos", t_chaos * 1e6,
        f"goodput_vs_fault_free={goodput_ratio:.2f} "
        f"faults={len(pins)}/{n_disp}_dispatches recoveries={recov} "
        f"modeled_recovery_steps_isolated={m_iso} "
        f"modeled_recovery_steps_global_restart={m_glob} "
        "(NaN-in-state pinned at 5% of windows, quarantine + masked "
        "re-prefill; cost_model.serve_recovery_steps)",
    ))

    # serve_fleet: goodput under one replica kill vs a fault-free fleet —
    # the same blast-radius argument one level up.  Three replicas share
    # the queue through the fleet router; the victim is killed at a
    # pinned ~5% point of its dispatch schedule, its live memory is
    # discarded, and its in-flight requests resume on survivors from its
    # last atomic snapshot — asserted bit-identical to the fault-free
    # fleet run, so goodput degrades by the handoff replay only.  The
    # modeled columns: serve_recovery_steps (one victim's isolated
    # replay) and serve_fleet_drain (recovery-aware vs depth-blind
    # routing of the remaining work over survivors carrying that debt).
    import shutil
    import tempfile

    from repro.serve.fleet import FleetRouter

    n_rep = 3
    f_engines = [ServeEngine(s_cfg, s_params, max_len=96,
                             decode_window=s_window)
                 for _ in range(n_rep)]

    def run_fleet(kill_at=()):
        f_chaos = None
        if kill_at:
            f_chaos = [None] * n_rep
            f_chaos[1] = ChaosInjector(seed=7, replica_kill_at=kill_at)
        root = tempfile.mkdtemp(prefix="bench_fleet_")
        try:
            fl = FleetRouter(
                f_engines, s_reqs, slots=slots, snapshot_every=1,
                snapshot_root=root, checksum_every=2, chaos=f_chaos)
            outs = fl.run()
        finally:
            shutil.rmtree(root, ignore_errors=True)
        assert sum(o.size for o in outs) == useful
        return fl, outs

    fl_ref, f_ref_outs = run_fleet()            # compile warm-up + reference
    f_disp = sum(s["decode_dispatches"] for s in fl_ref.stats_by_replica())
    f_kill = (max(1, round(0.05 * f_disp)),)
    fl_kill, f_kill_outs = run_fleet(f_kill)
    assert fl_kill.stats["replica_deaths"] == 1
    for want, got in zip(f_ref_outs, f_kill_outs):   # handoff bit-identity
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
    t_fref = t_fkill = float("inf")
    for _ in range(max(1, r_i // 4)):
        t0 = time.perf_counter()
        run_fleet()
        t_fref = min(t_fref, time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_fleet(f_kill)
        t_fkill = min(t_fkill, time.perf_counter() - t0)
    f_goodput = t_fref / t_fkill
    # Victim mid-flight at the kill: isolated replay of its accepted
    # prefix, then the remaining work drained over two survivors, one of
    # them carrying that replay as recovery debt.
    f_iso, _ = serve_recovery_steps(
        [pl for pl, _ in spec], [nn // 2 for _, nn in spec], 0, s_window)
    f_aware, f_blind = serve_fleet_drain(
        [pl + nn for pl, nn in spec], [0, f_iso], s_window)
    rows.append((
        "serve_fleet", t_fkill * 1e6,
        f"goodput_vs_fault_free={f_goodput:.2f} replicas={n_rep} "
        f"kill_at_dispatch={f_kill[0]}/{f_disp} "
        f"handoffs={fl_kill.stats['handoffs']} "
        f"modeled_recovery_steps_isolated={f_iso} "
        f"modeled_drain_aware={f_aware} modeled_drain_blind={f_blind} "
        "(one replica killed at ~5% of fleet dispatches, snapshot "
        "handoff to survivors, streams bit-identical; "
        "cost_model.serve_recovery_steps + serve_fleet_drain)",
    ))

    # serve_paged: pooled KV pages + recurrent-state prefix sharing — the
    # admission-cost dual of the storage argument.  N requests share one
    # long system prefix; the paged engine prefills its page-aligned head
    # ONCE (KV pages shared read-only, WKV S / RG-LRU h copied into each
    # slot) while the dense engine re-prefills prefix + suffix per
    # request.  Budget-1 requests finish at admission, so wall-clock IS
    # admission cost.  gemma3 (attention archs are split-prefill exact at
    # any suffix length); streams asserted bit-identical to dense, and
    # the pool — sized to the workload's page need — asserted strictly
    # below the dense slots x max_len footprint.
    from repro.serve import paging as paging_mod

    p_ml = 96 if smoke else 1024
    p_prefix = 40 if smoke else 1000
    p_sfx = (3, 5) if smoke else (8, 12, 16, 20, 23, 10)
    p_cfg = get_config("gemma3-1b").reduced()
    p_params = model_mod.init_params(p_cfg, jax.random.key(1))
    prefix_toks = rng.integers(0, p_cfg.vocab_size, (p_prefix,)).astype(
        np.int32)
    p_reqs = [
        Request(tokens=np.concatenate([
            prefix_toks,
            rng.integers(0, p_cfg.vocab_size, (k,)).astype(np.int32)]),
            max_new_tokens=1)
        for k in p_sfx
    ]
    aligned = (p_prefix // 32) * 32
    # Size the pool to the workload's actual page need (probe the node
    # geometry host-side): a loose pool would still be correct but would
    # forfeit the footprint claim the row exists to check.
    nsh = aligned // 32
    probe = paging_mod.PagedController(
        p_cfg,
        model_mod.abstract_decode_state(
            p_cfg, batch=2, max_len=p_ml, insert_window=32,
            paged=model_mod.PageSpec(page_size=32, shared_pages=nsh)),
        batch=2, max_len=p_ml, shared_map={0: (1, nsh)})
    worst = max(pl.tokens.size for pl in p_reqs) + 1
    p_pool = 2 * max(priv for _, _, priv in
                     probe.pages_needed(worst, aligned))
    d_eng = ServeEngine(p_cfg, p_params, max_len=p_ml, decode_window=4)
    p_eng = ServeEngine(p_cfg, p_params, max_len=p_ml, decode_window=4,
                        paged=True, pool_pages=p_pool)
    p_pid = p_eng.register_prefix(prefix_toks)
    warm_reqs = [Request(tokens=r.tokens, max_new_tokens=1,
                         prefix_id=p_pid) for r in p_reqs]
    d_outs = d_eng.serve(p_reqs, slots=2)       # compile warm-up + reference
    p_outs = p_eng.serve(warm_reqs, slots=2)    # + prefix-entry prefill
    for d_o, p_o in zip(d_outs, p_outs):        # acceptance: bit-identity
        assert d_o.outcome == p_o.outcome
        np.testing.assert_array_equal(d_o.tokens, p_o.tokens)
    pg = p_eng.last_paged_stats
    assert pg["page_table_violations"] == 0
    # Strict footprint win at measurement shapes; smoke shapes are too
    # small to show it (one shared page), so only require no regression.
    if smoke:
        assert pg["pool_bytes"] <= pg["dense_bytes"], pg
    else:
        assert pg["pool_bytes"] < pg["dense_bytes"], pg
    t_cold = t_warm = float("inf")
    for _ in range(max(1, r_i // 2)):
        t0 = time.perf_counter()
        d_eng.serve(p_reqs, slots=2)
        t_cold = min(t_cold, time.perf_counter() - t0)
        t0 = time.perf_counter()
        p_eng.serve(warm_reqs, slots=2)
        t_warm = min(t_warm, time.perf_counter() - t0)
    ratio = t_cold / t_warm
    m_shared, m_cold = serve_prefix_admission(
        p_prefix, int(np.mean(p_sfx)), len(p_reqs), 32)
    m_ratio = m_cold / m_shared
    rows.append((
        "serve_paged", t_warm * 1e6,
        f"admission_ratio_measured={ratio:.2f} "
        f"admission_ratio_modeled={m_ratio:.2f} target_ratio=3 "
        f"status={'ok' if (ratio >= 3 and m_ratio >= 3) or smoke else 'MISS'} "
        f"prefix_len={p_prefix} requests={len(p_reqs)} "
        f"pool_bytes={pg['pool_bytes']} dense_bytes={pg['dense_bytes']} "
        f"peak_mapped_bytes={pg['peak_mapped_bytes']} "
        "(budget-1 admissions: shared prefix pages + copied recurrent "
        "state vs per-request re-prefill; "
        "cost_model.serve_prefix_admission)",
    ))

    # blockwise attention vs full-matrix reference (memory win).
    q_shape = (1, 2, 256, 32) if smoke else (1, 4, 2048, 64)
    blk = 64 if smoke else 256
    q = jnp.asarray(rng.standard_normal(q_shape).astype(np.float32))
    t_block = _time(
        lambda q_: attention_blockwise(q_, q_, q_, causal=True, block=blk),
        q, reps=r_t,
    )
    t_full = _time(lambda q_: attention_ref(q_, q_, q_, causal=True), q,
                   reps=r_t)
    rows.append(("attention_blockwise", t_block, f"full_ref_us={t_full:.0f}"))

    # elevator shift primitive.
    big = jnp.asarray(
        rng.standard_normal(1 << (14 if smoke else 20)).astype(np.float32))
    t_shift = _time(lambda v: from_thread_or_const(v, 5, 0.0, window=4096),
                    big, reps=r_t)
    rows.append(("from_thread_or_const_1M", t_shift, "window=4096"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return [
        {"name": name, "us_per_call": round(us, 1), "derived": derived}
        for name, us, derived in rows
    ]


if __name__ == "__main__":
    main()
