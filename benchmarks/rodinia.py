"""Paper §5 benchmark suite analog (Fig. 11 speedup / Fig. 12 energy).

Each kernel from Table 3 is implemented twice on identical math:

  * ``shared``  — the von-Neumann GPGPU pattern: intermediates staged
    through an explicitly materialized buffer behind a barrier
    (``core.scratchpad``), exactly Fig. 1b / 2a;
  * ``direct``  — dMT-CGRA inter-thread communication: elevator shifts /
    eLDST forwarding (``core.elevator`` / ``core.eldst``), Fig. 1c / 2b.

Reported per kernel:
  - wall-clock speedup of direct over shared (this container's CPU; the
    barrier blocks XLA fusion the same way a scratchpad round-trip blocks
    in-fabric forwarding),
  - memory-traffic / energy reduction from the cost model (the
    hardware-independent quantity behind the paper's Fig. 12),
  - critical-path depth (explains the paper's BPNN slowdown: chains of
    adjacent-thread dependencies serialize).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    barrier,
    cost_model,
    from_thread_or_const,
    from_thread_or_const_nd,
    from_thread_or_mem,
    linear_scan,
)

N = 1 << 16          # default thread-block-scale problem size
MAT = 256            # matmul / lud dimension
GRID = (256, 512)    # stencil grid


def _time(fn, *args, reps=20):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


# --------------------------------------------------------------------------
# Kernels: (shared_fn, direct_fn, cost_fn, critical_path_{shared,direct})
# --------------------------------------------------------------------------

def scan_shared(x):
    # Hillis-Steele in "shared memory": barrier between every level.
    out = x
    shift = 1
    while shift < x.shape[0]:
        out = barrier(out)                     # __syncthreads
        shifted = jnp.pad(out, (shift, 0))[: x.shape[0]]
        out = out + shifted
        shift *= 2
    return out


def scan_direct(x):
    # Paper Fig. 6: fromThreadOrConst<sum, 1, 0> folded into the firing rule.
    return linear_scan(jnp.ones_like(x), x)


def matmul_shared(a, b):
    a_s = barrier(a)                           # stage A tile + barrier
    b_s = barrier(b)
    return a_s @ b_s


def matmul_direct(a, b):
    # Operand forwarding: values flow producer->consumer (XLA keeps tiles
    # resident; on TPU this is the matmul_fwd kernel's block reuse).
    return a @ b


def conv_shared(x, k):
    x_s = barrier(jnp.pad(x, (1, 1)))          # staged padded image
    return x_s[:-2] * k[0] + x_s[1:-1] * k[1] + x_s[2:] * k[2]


def conv_direct(x, k):
    # Fig. 1c: neighbors arrive as elevator shifts, margins as constant C.
    left = from_thread_or_const(x, 1, 0.0)
    right = from_thread_or_const(x, -1, 0.0)
    return left * k[0] + x * k[1] + right * k[2]


def reduce_shared(x):
    out = x
    n = x.shape[0]
    while n > 1:
        out = barrier(out)
        half = n // 2
        out = out[:half] + out[half:n]
        n = half
    return out[0]


def reduce_direct(x):
    # Windowed elevator tree: each level forwards partial sums point-to-point.
    out = x
    n = x.shape[0]
    while n > 1:
        half = n // 2
        partner = from_thread_or_const(out[:n], -half, 0.0)[:half]
        out = out[:half] + partner
        n = half
    return out[0]


def lud_shared(a):
    # One lud_internal step: stage the pivot row/col, barrier, update trail.
    pivot_row = barrier(a[0, 1:])
    pivot_col = barrier(a[1:, 0] / a[0, 0])
    return a[1:, 1:] - jnp.outer(pivot_col, pivot_row)


def lud_direct(a):
    pivot_row = a[0, 1:]
    pivot_col = a[1:, 0] / a[0, 0]
    return a[1:, 1:] - jnp.outer(pivot_col, pivot_row)


def _stencil_shared(x, c):
    xs = barrier(jnp.pad(x, 1))
    return (c[0] * xs[1:-1, 1:-1] + c[1] * xs[:-2, 1:-1] + c[2] * xs[2:, 1:-1]
            + c[3] * xs[1:-1, :-2] + c[4] * xs[1:-1, 2:])


def _stencil_direct(x, c):
    up = from_thread_or_const_nd(x, (1, 0), 0.0)
    down = from_thread_or_const_nd(x, (-1, 0), 0.0)
    left = from_thread_or_const_nd(x, (0, 1), 0.0)
    right = from_thread_or_const_nd(x, (0, -1), 0.0)
    return c[0] * x + c[1] * up + c[2] * down + c[3] * left + c[4] * right


def hotspot_shared(x):
    c = jnp.asarray([0.6, 0.1, 0.1, 0.1, 0.1])
    return _stencil_shared(x, c)


def hotspot_direct(x):
    c = jnp.asarray([0.6, 0.1, 0.1, 0.1, 0.1])
    return _stencil_direct(x, c)


def srad_shared(x):
    # SRAD diffusion step (simplified coefficients; same stencil pattern).
    c = jnp.asarray([1.0, -0.25, -0.25, -0.25, -0.25])
    return _stencil_shared(x, c)


def srad_direct(x):
    c = jnp.asarray([1.0, -0.25, -0.25, -0.25, -0.25])
    return _stencil_direct(x, c)


def pathfinder_shared(cost, cur):
    cur_s = barrier(cur)
    left = jnp.pad(cur_s, (1, 0), constant_values=jnp.inf)[:-1]
    right = jnp.pad(cur_s, (0, 1), constant_values=jnp.inf)[1:]
    return cost + jnp.minimum(cur_s, jnp.minimum(left, right))


def pathfinder_direct(cost, cur):
    left = from_thread_or_const(cur, 1, jnp.inf)
    right = from_thread_or_const(cur, -1, jnp.inf)
    return cost + jnp.minimum(cur, jnp.minimum(left, right))


def bpnn_shared(w, x):
    # layerforward: staged partial products + barriered tree sum.
    prod = barrier(w * x[None, :])
    return jax.nn.sigmoid(prod.sum(axis=1))


def bpnn_direct(w, x):
    # Paper preserves the original adjacent-thread chain: each thread adds
    # its product to the previous thread's partial sum (Δ=1 elevator) —
    # a serial chain, which is why the paper reports a ~40% slowdown.
    prod = w * x[None, :]
    sums = linear_scan(jnp.ones_like(prod), prod, axis=1)[:, -1]
    return jax.nn.sigmoid(sums)


# --------------------------------------------------------------------------
# Performance model (the Fig. 11 analog)
# --------------------------------------------------------------------------
# Wall-clock on one CPU core cannot express the paper's hardware point (a
# barrier costs ~nothing on a cache-coherent core).  The Fig. 11 analog is a
# bottleneck model with Fermi-class per-SM constants vs. the paper's
# 140-unit CGRA core (Table 2):

GPU_LANES = 32                # CUDA cores per SM
CGRA_UNITS = 140              # dMT-CGRA functional units (Table 2)
CLOCK = 1.4e9                 # both cores clock at 1.4 GHz (Table 2)
DRAM_BW = 177e9 / 15          # GTX480 DRAM bandwidth per SM (B/s)
SPAD_BW = GPU_LANES * 4 * CLOCK / 2   # shared-memory B/s per SM (bank-limited)
FABRIC_BW = CGRA_UNITS * 4 * CLOCK    # producer->consumer forwarding B/s
BARRIER_CYCLES = 100          # per __syncthreads (drain + refill)


def modeled_time_shared(cost: "cost_model.KernelCost", n_threads: int,
                        n_barriers: float) -> float:
    t_compute = cost.flops / (GPU_LANES * CLOCK)
    t_mem = (cost.traffic.dram_bytes / DRAM_BW
             + cost.traffic.scratchpad_bytes / SPAD_BW)
    # A barrier stalls the whole block: every warp must arrive.
    t_sync = n_barriers * (BARRIER_CYCLES + n_threads / GPU_LANES) / CLOCK
    return max(t_compute, t_mem) + t_sync


def modeled_time_direct(cost: "cost_model.KernelCost", critical_path: float,
                        width: float = float("inf")) -> float:
    # `width` = available thread-level parallelism; chains narrower than the
    # grid leave units idle (the paper's BPNN pathology).
    t_compute = cost.flops / (min(CGRA_UNITS, width) * CLOCK)
    t_mem = (cost.traffic.dram_bytes / DRAM_BW
             + cost.traffic.fabric_bytes / FABRIC_BW)
    # Dataflow firing: no barriers, but serial producer->consumer chains
    # bound latency by the chain length.
    t_chain = critical_path / CLOCK
    return max(t_compute, t_mem, t_chain)


# --------------------------------------------------------------------------
# Harness
# --------------------------------------------------------------------------

def run(reps: int = 20, smoke: bool = False) -> list[dict]:
    # Smoke mode (benchmarks/run.py --smoke): tiny shapes, one rep — the
    # point is exercising every code path (imports, kernel wiring, the
    # shared/direct parity asserts), not producing meaningful timings.
    n = 1 << 10 if smoke else N
    mat = 32 if smoke else MAT
    grid_hw = (16, 32) if smoke else GRID
    bp = (8, 64) if smoke else (64, 2048)

    rng = np.random.default_rng(0)
    x1 = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    k3 = jnp.asarray([0.25, 0.5, 0.25], jnp.float32)
    a_m = jnp.asarray(rng.standard_normal((mat, mat)).astype(np.float32))
    b_m = jnp.asarray(rng.standard_normal((mat, mat)).astype(np.float32))
    grid = jnp.asarray(rng.standard_normal(grid_hw).astype(np.float32))
    w_b = jnp.asarray(rng.standard_normal(bp).astype(np.float32) * 0.05)
    x_b = jnp.asarray(rng.standard_normal(bp[1]).astype(np.float32))
    pf_cost = jnp.asarray(rng.random(n).astype(np.float32))

    import math

    log2n = math.log2(n)
    cases = [
        # name, shared_fn, direct_fn, args, costs,
        #   n_barriers, n_threads, chain_direct, width_direct
        ("scan", scan_shared, scan_direct, (x1,),
         cost_model.scan_traffic(n), log2n, n, n, CGRA_UNITS),
        ("matrixMul", matmul_shared, matmul_direct, (a_m, b_m),
         cost_model.matmul_traffic(mat, mat, mat), 2 * mat / 16, mat * mat,
         mat, CGRA_UNITS),
        ("convolution", conv_shared, conv_direct, (x1, k3),
         cost_model.conv1d_traffic(n), 1, n, 2, CGRA_UNITS),
        ("reduce", reduce_shared, reduce_direct, (x1,),
         cost_model.reduce_traffic(n), log2n, n, log2n, CGRA_UNITS),
        ("lud", lud_shared, lud_direct, (a_m,),
         cost_model.matmul_traffic(mat - 1, 1, mat - 1), 2, mat * mat, 2,
         CGRA_UNITS),
        ("srad", srad_shared, srad_direct, (grid,),
         cost_model.stencil2d_traffic(*grid_hw), 1, grid_hw[0] * grid_hw[1],
         2, CGRA_UNITS),
        ("hotspot", hotspot_shared, hotspot_direct, (grid,),
         cost_model.stencil2d_traffic(*grid_hw), 1, grid_hw[0] * grid_hw[1],
         2, CGRA_UNITS),
        ("pathfinder", pathfinder_shared, pathfinder_direct, (pf_cost, x1),
         cost_model.stencil2d_traffic(1, n, pts=3), 1, n, 2, CGRA_UNITS),
        # BPNN keeps the original adjacent-thread chain (paper §5.2): only
        # bp[0] chains run concurrently -> width-limited + bp[1]-deep chain.
        ("bpnn", bpnn_shared, bpnn_direct, (w_b, x_b),
         cost_model.reduce_traffic(bp[0] * bp[1]), math.log2(bp[1]), bp[1],
         bp[1], bp[0]),
    ]

    rows = []
    for name, f_sh, f_di, args, costs, n_barriers, n_thr, chain, width in cases:
        sh = jax.jit(f_sh)
        di = jax.jit(f_di)
        out_sh = np.asarray(sh(*args), np.float32)
        out_di = np.asarray(di(*args), np.float32)
        np.testing.assert_allclose(out_sh, out_di, rtol=2e-3, atol=2e-3)
        t_sh = _time(sh, *args, reps=reps)
        t_di = _time(di, *args, reps=reps)
        naive, shared, direct = costs
        m_sh = modeled_time_shared(shared, n_thr, n_barriers)
        m_di = modeled_time_direct(direct, chain, width)
        rows.append({
            "name": name,
            "us_shared": t_sh,
            "us_direct": t_di,
            "speedup_wallclock": t_sh / t_di,
            "modeled_speedup": m_sh / m_di,
            "energy_shared_pj": shared.energy_pj,
            "energy_direct_pj": direct.energy_pj,
            "energy_reduction": shared.energy_pj / max(direct.energy_pj, 1e-9),
            "traffic_reduction": (
                (naive.traffic.dram_bytes + naive.traffic.scratchpad_bytes)
                / max(direct.traffic.dram_bytes, 1)
            ),
            "critical_path_direct": chain,
        })
    return rows


def main(smoke: bool = False):
    rows = run(reps=1 if smoke else 20, smoke=smoke)
    print("name,us_shared,us_direct,wallclock_speedup,modeled_speedup,"
          "energy_reduction,traffic_reduction,critical_path_direct")
    for r in rows:
        print(f"{r['name']},{r['us_shared']:.1f},{r['us_direct']:.1f},"
              f"{r['speedup_wallclock']:.2f},{r['modeled_speedup']:.2f},"
              f"{r['energy_reduction']:.2f},{r['traffic_reduction']:.1f},"
              f"{r['critical_path_direct']:.0f}")
    import math

    def geo(vals):
        return math.exp(sum(math.log(v) for v in vals) / len(vals))

    model_all = [r["modeled_speedup"] for r in rows]
    print(f"geomean_modeled_speedup,{geo(model_all):.2f}")
    print(f"max_modeled_speedup,{max(model_all):.2f}")
    en = [r["energy_reduction"] for r in rows]
    print(f"geomean_energy_reduction,{geo(en):.2f}")
    print("paper_reference,geomean 4.5x / max 13.5x speedup; 7x energy")


if __name__ == "__main__":
    main()
