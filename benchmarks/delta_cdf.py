"""Paper Fig. 5 analog: CDF of ΔTID transmission distances.

Collects every Δ used by the inter-thread communication sites across this
repo's benchmark implementations and model layers (token shift Δ=1..3,
scan carries Δ=1, stencil halos Δ=±1 row/col, reduction trees Δ=2^k,
windowed attention block forwarding, matmul forwarding Δ=1) and reports
the cumulative distribution, weighted by how many tokens each site moves.

The paper's claim: 87% of communication fits a 16-entry token buffer.
"""

from __future__ import annotations

import math

import numpy as np

# (site, delta, weight) — weight = values communicated per kernel execution
# at the reference sizes used in benchmarks/rodinia.py and the LM configs.
N = 1 << 16
GRID = (256, 512)


def collect_sites() -> list[tuple[str, int, float]]:
    sites: list[tuple[str, int, float]] = []
    # scan / prefix sum: Δ=1 chain over N threads.
    sites.append(("scan.carry", 1, N))
    # convolution taps: Δ=±1.
    sites.append(("conv.left", 1, N))
    sites.append(("conv.right", 1, N))
    # matmul operand forwarding: Δ=1 along rows and cols (paper Fig. 3).
    sites.append(("matmul.rowfwd", 1, 256 * 256))
    sites.append(("matmul.colfwd", 1, 256 * 256))
    # stencils: row Δ=±1 (one row of threads apart = 1 in 2D coords),
    # col Δ=±1.
    for s in ("hotspot", "srad"):
        for d in ("up", "down", "left", "right"):
            sites.append((f"{s}.{d}", 1, GRID[0] * GRID[1]))
    sites.append(("pathfinder.left", 1, N))
    sites.append(("pathfinder.right", 1, N))
    # reduction tree: Δ = 2^k, halving weight per level.
    n = N
    k = 0
    while n > 1:
        sites.append((f"reduce.l{k}", n // 2, n // 2))
        n //= 2
        k += 1
    # bpnn chain: Δ=1 over 2048-wide rows.
    sites.append(("bpnn.chain", 1, 64 * 2048))
    # LM token-shift (RWKV Δ=1, conv width 4 -> Δ=1..3).
    sites.append(("rwkv.token_shift", 1, 4096))
    for d in (1, 2, 3):
        sites.append((f"rglru.conv.d{d}", d, 4096))
    # chunked scan carries: Δ=1 over chunk space.
    sites.append(("elevator_scan.carry", 1, 4096 // 256))
    return sites


def cdf(sites):
    deltas = np.array([d for _, d, _ in sites], dtype=np.int64)
    weights = np.array([w for _, _, w in sites], dtype=np.float64)
    order = np.argsort(deltas)
    deltas, weights = deltas[order], weights[order]
    cum = np.cumsum(weights) / weights.sum()
    return deltas, cum


def fraction_within(buffer_size: int) -> float:
    deltas, cum = cdf(collect_sites())
    mask = deltas <= buffer_size
    if not mask.any():
        return 0.0
    return float(cum[mask.argmin() - 1] if not mask.all() else 1.0)


def main():
    sites = collect_sites()
    deltas, cum = cdf(sites)
    print("delta,cdf")
    seen = {}
    for d, c in zip(deltas, cum):
        seen[int(d)] = float(c)
    for d in sorted(seen):
        print(f"{d},{seen[d]:.4f}")
    f16 = fraction_within(16)
    print(f"fraction_delta_le_16,{f16:.4f}")
    print(f"paper_claim,0.87")


if __name__ == "__main__":
    main()
