"""Benchmark driver — one section per paper table/figure.

  rodinia      -> paper Fig. 11 (speedup) + Fig. 12 (energy) analogs
  delta_cdf    -> paper Fig. 5 (ΔTID CDF)
  kernel_bench -> per-kernel microbenchmarks
  roofline     -> §Roofline table from the dry-run artifacts (if present)

Prints ``name,us_per_call,derived`` CSV blocks.
"""

from __future__ import annotations


def main() -> None:
    from benchmarks import delta_cdf, kernel_bench, rodinia

    print("== rodinia (paper Fig. 11/12 analog) ==")
    rodinia.main()
    print()
    print("== delta CDF (paper Fig. 5 analog) ==")
    delta_cdf.main()
    print()
    print("== kernel microbenchmarks ==")
    kernel_bench.main()
    print()
    print("== roofline table (from dry-run artifacts) ==")
    try:
        from benchmarks import roofline_table

        roofline_table.main()
    except Exception as e:  # noqa: BLE001
        print(f"(roofline table unavailable: {e})")


if __name__ == "__main__":
    main()
