"""Benchmark driver — one section per paper table/figure.

  rodinia      -> paper Fig. 11 (speedup) + Fig. 12 (energy) analogs
  delta_cdf    -> paper Fig. 5 (ΔTID CDF)
  kernel_bench -> per-kernel microbenchmarks (also written to
                  BENCH_kernels.json at the repo root as the
                  machine-readable perf baseline for future PRs)
  roofline     -> §Roofline table from the dry-run artifacts (if present)

Prints ``name,us_per_call,derived`` CSV blocks.
"""

from __future__ import annotations

import json
import pathlib

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_kernels.json"


def main() -> None:
    from benchmarks import delta_cdf, kernel_bench, rodinia

    print("== rodinia (paper Fig. 11/12 analog) ==")
    rodinia.main()
    print()
    print("== delta CDF (paper Fig. 5 analog) ==")
    delta_cdf.main()
    print()
    print("== kernel microbenchmarks ==")
    kernel_rows = kernel_bench.main()
    BENCH_JSON.write_text(
        json.dumps({"schema": "kernel_bench.v1", "rows": kernel_rows}, indent=2)
        + "\n"
    )
    print(f"(wrote {BENCH_JSON})")
    print()
    print("== roofline table (from dry-run artifacts) ==")
    try:
        from benchmarks import roofline_table

        roofline_table.main()
    except Exception as e:  # noqa: BLE001
        print(f"(roofline table unavailable: {e})")


if __name__ == "__main__":
    main()
