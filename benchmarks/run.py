"""Benchmark driver — one section per paper table/figure.

  rodinia      -> paper Fig. 11 (speedup) + Fig. 12 (energy) analogs
  delta_cdf    -> paper Fig. 5 (ΔTID CDF)
  kernel_bench -> per-kernel microbenchmarks (also written to
                  BENCH_kernels.json at the repo root as the
                  machine-readable perf baseline for future PRs)
  roofline     -> §Roofline table from the dry-run artifacts (if present)

Prints ``name,us_per_call,derived`` CSV blocks.

``--smoke`` runs every section on tiny shapes with no timing loops — a
CI-speed regression check for the bench *paths* (import errors, dispatch
wiring, schema drift fail loudly instead of rotting until the next real
bench run).  Smoke mode validates the row schema but never overwrites
BENCH_kernels.json: tiny-shape timings are not a baseline.
"""

from __future__ import annotations

import argparse
import json
import pathlib

BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_kernels.json"

_ROW_KEYS = {"name", "us_per_call", "derived"}


def _validate_rows(rows: list[dict]) -> None:
    """Schema check (kernel_bench.v1): smoke mode's replacement for the
    baseline write — drift fails tier-1 instead of corrupting the json."""
    if not rows:
        raise SystemExit("kernel_bench produced no rows")
    for row in rows:
        if set(row) != _ROW_KEYS:
            raise SystemExit(f"kernel_bench row schema drift: {sorted(row)}")
    if BENCH_JSON.exists():
        baseline = json.loads(BENCH_JSON.read_text())
        if baseline.get("schema") != "kernel_bench.v1":
            raise SystemExit(
                f"BENCH_kernels.json schema drift: {baseline.get('schema')}"
            )


def main(smoke: bool = False) -> None:
    from benchmarks import delta_cdf, kernel_bench, rodinia

    print("== rodinia (paper Fig. 11/12 analog) ==")
    rodinia.main(smoke=smoke)
    print()
    print("== delta CDF (paper Fig. 5 analog) ==")
    delta_cdf.main()
    print()
    print("== kernel microbenchmarks ==")
    kernel_rows = kernel_bench.main(smoke=smoke)
    if smoke:
        _validate_rows(kernel_rows)
        print("(smoke mode: schema validated, BENCH_kernels.json untouched)")
    else:
        BENCH_JSON.write_text(
            json.dumps({"schema": "kernel_bench.v1", "rows": kernel_rows},
                       indent=2)
            + "\n"
        )
        print(f"(wrote {BENCH_JSON})")
    print()
    print("== roofline table (from dry-run artifacts) ==")
    try:
        from benchmarks import roofline_table

        roofline_table.main()
    except Exception as e:  # noqa: BLE001
        print(f"(roofline table unavailable: {e})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no timing loops, no baseline write")
    main(smoke=ap.parse_args().smoke)
