"""Render the §Roofline table from experiments/dryrun/*.json."""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _norm(s: str) -> str:
    return s.replace("-", "_").replace(".", "_")


def load_cells(mesh: str = "16x16") -> list[dict]:
    # Dedupe dashed/underscored arch spellings: keep the newest artifact.
    by_key: dict[tuple, tuple[float, dict]] = {}
    for f in DRYRUN_DIR.glob(f"*__{mesh}.json"):
        d = json.loads(f.read_text())
        key = (_norm(d["arch"]), d["shape"])
        mtime = f.stat().st_mtime
        if key not in by_key or mtime > by_key[key][0]:
            by_key[key] = (mtime, d)
    return [d for _, (_, d) in sorted(by_key.items())]


def fmt_row(c: dict) -> str:
    if c.get("skipped"):
        return (f"| {c['arch']} | {c['shape']} | — | — | — | — | — | skip | "
                f"{c['skipped'][:42]}… |")
    if not c.get("ok"):
        return f"| {c['arch']} | {c['shape']} | FAIL | | | | | | {c.get('error','')[:40]} |"
    r = c.get("roofline")
    if not r:
        return f"| {c['arch']} | {c['shape']} | compiled (no roofline) | | | | | | |"
    peak = c["memory"]["peak_estimate_bytes"] / 2**30
    ratio = c.get("useful_flops_ratio")
    return (
        f"| {c['arch']} | {c['shape']} | {r['compute_s']:.4f} | {r['memory_s']:.3f} "
        f"| {r['memory_analytic_s']:.4f} | {r['collective_s']:.4f} "
        f"| {r.get('dominant_fused', r['dominant'])} | {peak:.1f} "
        f"| {ratio:.2f} |" if ratio else
        f"| {c['arch']} | {c['shape']} | {r['compute_s']:.4f} | {r['memory_s']:.3f} "
        f"| {r['memory_analytic_s']:.4f} | {r['collective_s']:.4f} "
        f"| {r.get('dominant_fused', r['dominant'])} | {peak:.1f} | n/a |"
    )


def main():
    print("| arch | shape | compute_s | mem_hlo_s | mem_fused_s | coll_s | dominant | peak_GiB | useful_flops |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in load_cells("16x16"):
        print(fmt_row(c))
    print()
    print("multi-pod (2x16x16) compile status:")
    for c in load_cells("2x16x16"):
        status = "skip" if c.get("skipped") else ("ok" if c["ok"] else "FAIL")
        peak = c.get("memory", {}).get("peak_estimate_bytes")
        peak_s = f" peak={peak/2**30:.1f}GiB" if peak else ""
        print(f"  {c['arch']:24s} {c['shape']:12s} {status}{peak_s}")


if __name__ == "__main__":
    main()
